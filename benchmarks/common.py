"""Shared benchmark harness bits: tiny model factory, timing, CSV output."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ART = Path(__file__).resolve().parent / "artifacts"
ART.mkdir(exist_ok=True)


def bench_model(d=64, layers=2, vocab=256, heads=4):
    from repro.configs.base import ATTN, ModelConfig, Segment
    return ModelConfig(
        name=f"bench-{d}x{layers}",
        family="dense", d_model=d, n_heads=heads, n_kv_heads=heads,
        d_ff=2 * d, vocab_size=vocab,
        segments=(Segment((ATTN,), layers),), dtype="float32")


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def iqm(ts):
    """Interquartile mean: sheds GC / neighbour-interference spikes that
    otherwise dominate CPU wall-clock at benchmark scale."""
    ts = np.sort(np.asarray(ts))
    lo, hi = len(ts) // 4, max(3 * len(ts) // 4, len(ts) // 4 + 1)
    return float(np.mean(ts[lo:hi]))


def emit(name, us, derived=""):
    print(f"{name},{us if us is not None else ''},{derived}", flush=True)


def save_json(name, obj):
    (ART / f"{name}.json").write_text(json.dumps(obj, indent=2, default=float))

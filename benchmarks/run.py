"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (+ the roofline report). Prints
``name,us_per_call,derived`` CSV lines; artifacts land in
benchmarks/artifacts/. Training-loop suites run through the public
``repro.api`` facade — there is no benchmark-local trainer wiring.

Suites in ``ARTIFACTS`` own a committed JSON artifact: after a suite
"succeeds", the orchestrator verifies the file was actually (re)written
this run and fails LOUDLY otherwise — a suite that silently returns
without its artifact is how BENCH_*.json files go stale or missing.

Subsets: ``python -m benchmarks.run fig1 fig3 roofline``
"""
from __future__ import annotations

import sys
import time
import traceback

# suite -> the artifact (benchmarks/artifacts/<name>.json) it must write
ARTIFACTS = {
    "sampler": "BENCH_sampler",
    "pipeline": "BENCH_pipeline",
    "fused": "BENCH_fused",
    "selection": "BENCH_selection",
    "obs": "BENCH_obs",
    "scoring_overlap": "BENCH_scoring",
    "score_prune": "BENCH_prune",
}


def main() -> None:
    from benchmarks import paper_figures as pf
    from benchmarks import (data_plane, fused_presample, obs_overhead,
                            roofline, sampler_compare, score_prune,
                            scoring_overhead, selection_scale, svrg_compare)
    from benchmarks.common import ART

    suites = {
        "sampler": sampler_compare.sampler_compare,
        "pipeline": data_plane.bench_data_plane,
        "fused": fused_presample.bench_fused_presample,
        "selection": selection_scale.bench_selection_scale,
        "obs": obs_overhead.bench_obs_overhead,
        "fig1": pf.fig1_variance_reduction,
        "fig2": pf.fig2_correlation,
        "fig3": pf.fig3_convergence,
        "fig4": pf.fig4_finetune,
        "fig5": pf.fig5_sequence,
        "fig7": pf.fig7_ablation_B,
        "tau": pf.tau_gate_behaviour,
        "scoring": scoring_overhead.scoring_overhead,
        "scoring_overlap": scoring_overhead.bench_scoring_overlap,
        "score_prune": score_prune.bench_score_prune,
        "svrg": svrg_compare.svrg_compare,
        "roofline": lambda: roofline.render(emit=print),
    }
    wanted = sys.argv[1:] or list(suites)
    unknown = [w for w in wanted if w not in suites]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; have {sorted(suites)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        t0 = time.time()
        try:
            suites[name]()
            art = ARTIFACTS.get(name)
            if art is not None:
                path = ART / f"{art}.json"
                if not path.exists() or path.stat().st_mtime < t0 - 1:
                    raise RuntimeError(
                        f"suite '{name}' completed without writing "
                        f"{path} — artifact contract broken")
            print(f"{name}.elapsed_s,,{time.time() - t0:.1f}", flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}.ERROR,,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Selection-plane scaling: per-plan O(n) gather vs sharded O(b·H).

Measures ONE host's critical-path work for a single history-style
proportional selection plan, sweeping dataset size n × simulated host
count H:

* ``gather`` — what ``imp.selection_impl="gather"`` pays per plan: pad
  this host's shard, interleave the all-gathered stack back into the
  global score vector (the host-side half of
  ``collectives.gather_host_scores``), build the smoothed distribution
  over all n slots, and draw b ids with ``rng.choice`` — every step of
  it O(n).
* ``sharded`` — what ``imp.selection_impl="sharded"`` pays: this shard's
  sufficient stats (O(n/H)) + the O(H) stat reduction, exponential-race
  keys + local bottom-(b+1) over the shard (O(n/H)), and the
  deterministic merge of the (b+1)·H exchanged candidates.

Peer contributions (other hosts' padded shards / stats / candidate
blocks) are precomputed OUTSIDE the timed region — on a real pod they
are computed concurrently on the other hosts, so the critical path is
one host's work plus the exchange. Network time is NOT simulated; the
bytes moved per plan are reported instead (4n per host for the gather
vs ~20·(b+1)·H for the exchange), so the wall-clock gap here is a LOWER
bound on the real one.

Stats are interquartile means over per-plan wall-clock — regenerate only
on an idle machine. Artifact: benchmarks/artifacts/BENCH_selection.json.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, iqm, save_json

B_GLOBAL = 64          # the drawn batch per plan
SMOOTHING, TEMP = 0.1, 1.0
SEED, SALT = 0, 9173


def _shards(n: int, H: int, frac_seen=0.9, seed=1):
    from repro.sampler import ScoreStore
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0.05, 6.0, n).astype(np.float32)
    seen_ids = np.flatnonzero(rng.uniform(size=n) < frac_seen)
    stores = []
    for h in range(H):
        st = ScoreStore(n, host_id=h, n_hosts=H)
        st.update(seen_ids, scores[seen_ids])
        stores.append(st)
    return stores


def bench_gather_path(stores, n, trials):
    """Host 0's per-plan cost on the O(n) gather path."""
    from repro.distributed.collectives import interleave_shards, pad_shard
    from repro.sampler import ScoreStore
    H = len(stores)
    # the allgather RESULT (peers' padded shards) exists before the
    # host-side reassembly starts; host 0 still pays its own pad
    stack = np.stack([pad_shard(s.sentinel_scores(), n, H) for s in stores])
    ts = []
    for t in range(trials):
        t0 = time.perf_counter()
        stack[0] = pad_shard(stores[0].sentinel_scores(), n, H)
        sg = interleave_shards(stack, n)
        p = ScoreStore.distribution_from(sg, SMOOTHING, TEMP)
        rng = np.random.default_rng(
            np.random.SeedSequence([SEED, SALT, t]))
        gids = rng.choice(n, size=B_GLOBAL, replace=True, p=p)
        w = (1.0 / (n * p[gids])).astype(np.float32)
        ts.append(time.perf_counter() - t0)
        assert w.shape == (B_GLOBAL,)
    return iqm(ts)


def bench_sharded_path(stores, n, trials):
    """Host 0's per-plan cost on the sharded exchange path."""
    from repro.sampler import selection
    H = len(stores)
    kc = B_GLOBAL + 1
    peer_stats = [selection.shard_stats(s.scores, s.seen, TEMP)
                  for s in stores[1:]]
    ts = []
    for t in range(trials):
        ctx = selection.hash_context(SEED, SALT, t)
        # peers' candidate blocks arrive via the exchange; they are
        # computed concurrently on the other hosts → not on this host's
        # critical path
        if H > 1:
            stats_all = np.stack(
                [selection.shard_stats(stores[0].scores, stores[0].seen,
                                       TEMP)] + peer_stats).sum(axis=0)
            dist_pre = selection.GlobalDist(stats_all, n, SMOOTHING, TEMP)
            peer_blocks = [selection.local_candidates(
                s.scores, s.seen, s.global_ids(np.arange(s.n_local)),
                dist_pre, kc, ctx=ctx) for s in stores[1:]]
        t0 = time.perf_counter()
        local = selection.shard_stats(stores[0].scores, stores[0].seen, TEMP)
        stats = (np.stack([local] + peer_stats).sum(axis=0)
                 if H > 1 else local)
        dist = selection.GlobalDist(stats, n, SMOOTHING, TEMP)
        blk = selection.local_candidates(
            stores[0].scores, stores[0].seen,
            stores[0].global_ids(np.arange(stores[0].n_local)),
            dist, kc, ctx=ctx)
        blocks = [blk] + peer_blocks if H > 1 else [blk]
        cand = {k: np.concatenate([b[k] for b in blocks]) for k in blk}
        gids, probs, thr = selection.merge_topk(cand, B_GLOBAL)
        w = selection.ht_weights(probs, thr, n)
        ts.append(time.perf_counter() - t0)
        assert w.shape == (B_GLOBAL,)
    return iqm(ts)


def bench_selection_scale(ns=(10_000, 100_000, 1_000_000),
                          hosts=(1, 8, 32), trials=30):
    """O(n) gather vs sharded top-k exchange → BENCH_selection.json."""
    out = {"b": B_GLOBAL, "trials": trials}
    for n in ns:
        for H in hosts:
            stores = _shards(n, H)
            g_ms = bench_gather_path(stores, n, trials) * 1e3
            s_ms = bench_sharded_path(stores, n, trials) * 1e3
            key = f"n{n}.h{H}"
            out[key] = {
                "n": n, "hosts": H,
                "gather_ms_per_plan": round(g_ms, 4),
                "sharded_ms_per_plan": round(s_ms, 4),
                "speedup": round(g_ms / s_ms, 2),
                # payload a host must receive per plan (f32 scores vs
                # (gid i64 + key f64 + prob f64) candidate rows)
                "gather_bytes": 4 * n,
                "exchange_bytes": 24 * (B_GLOBAL + 1) * H,
            }
            emit(f"selection.{key}.gather_ms", round(g_ms, 3))
            emit(f"selection.{key}.sharded_ms", round(s_ms, 3))
            emit(f"selection.{key}.speedup", None,
                 f"gather/sharded={g_ms / s_ms:.2f}")
    save_json("BENCH_selection", out)
    return out


if __name__ == "__main__":
    bench_selection_scale()

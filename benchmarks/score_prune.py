"""Survival-pruned scoring: measured block-skip fraction vs the ideal.

Sweeps presample ratio ∈ {2, 3, 5} × seq-len over a MODELED pool — rows
get a lognormal-ish difficulty spread (per-row margin on the true
label) and ragged supervised lengths (uniform in [T/4, T], the packed
LM batch shape): the concentrated-score regime importance sampling
exists for. Raggedness matters to the pruner — ``rem_after`` counts
only supervised tokens, so short rows exhaust their score headroom
early and die at the first checkpoints, exactly as in real pools.
The pruned pass's receipt gives the measured skip fraction
``blocks_skipped / tiles_total``; the ideal is what a clairvoyant
pruner would skip, killing every raced-out loser at the FIRST
checkpoint: ``(1 − (k+1)/B) · (nc − 1)/nc`` → 1 − 1/ratio for deep
chunking. Uniform-score pools sit well under the ideal (bounds stay
loose when everyone is alike); the modeled pool must reach ≥ 40% skip
at ratio 3 — below that the bound math has regressed and this suite
FAILS, loudly.

Wall-clock here is interpret-mode (CPU executes the kernel bodies
either way), so the flop receipt, not time, is the savings claim.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.kernels.fused_presample.ops import pruned_pool_score

RATIO3_FLOOR = 0.40


def _modeled_pool(rng, B, T, V):
    """Concentrated difficulty: per-row true-label margin a_i ~ N(2, 3) —
    high-margin rows are nearly solved (score → 0), low/negative margins
    are the hard tail the race keeps. Score spread ends up lognormal-ish,
    like a real mid-training pool; supervised lengths are ragged
    (uniform [T/4, T]), like packed sequences under EOS truncation."""
    a = rng.normal(2.0, 3.0, (B, 1)).astype(np.float32)
    y = rng.integers(0, V, (B, T)).astype(np.int32)
    z = rng.normal(0.0, 0.3, (B, T, V)).astype(np.float32)
    z[np.arange(B)[:, None], np.arange(T)[None, :], y] += a
    lengths = rng.integers(T // 4, T + 1, B)
    y[np.arange(T)[None, :] >= lengths[:, None]] = -1
    return jnp.asarray(z), jnp.asarray(y)


def bench_score_prune(ratios=(2, 3, 5), seq_lens=(64, 128), b=16, V=128):
    rng = np.random.default_rng(77)
    out = {"b": b, "vocab": V, "ratio3_floor": RATIO3_FLOOR, "cells": []}
    worst_r3 = 1.0
    for ratio in ratios:
        for T in seq_lens:
            B = ratio * b
            z, y = _modeled_pool(rng, B, T, V)
            _, alive, _, stats = pruned_pool_score(z, y, 0xB0B0 + ratio, k=b)
            killed, skipped, total, flops = map(float, np.asarray(stats))
            frac = skipped / total
            # these pools run at row granularity (block_b=1, B < 128)
            # with chunk_t = block_t, so tiles_total = nc · B
            nc = total / B
            ideal = (1.0 - (b + 1) / B) * (nc - 1) / nc
            cell = {"ratio": ratio, "T": T, "B": B,
                    "rows_killed": killed, "blocks_skipped": skipped,
                    "tiles_total": total, "skip_frac": frac,
                    "ideal_frac": ideal, "flops_saved": flops}
            out["cells"].append(cell)
            emit(f"score_prune.r{ratio}.T{T}", None,
                 f"skip={frac:.2f}/ideal={ideal:.2f} killed={killed:.0f}/{B}")
            if ratio == 3:
                worst_r3 = min(worst_r3, frac)
    out["worst_ratio3_skip"] = worst_r3
    save_json("BENCH_prune", out)
    if worst_r3 < RATIO3_FLOOR:
        raise RuntimeError(
            f"ratio-3 block-skip {worst_r3:.2f} < {RATIO3_FLOOR} on the "
            f"modeled pool: the conservative bound stopped biting")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_score_prune()

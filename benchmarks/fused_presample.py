"""Fused device presample benchmark (the PR-7 tentpole's perf evidence).

End-to-end training step wall-clock with Algorithm 1's presample scheme,
comparing the two engine-backed implementations over ratio × batch:

* ``host``  — ``presample_host``: the candidate pool is assembled INLINE
  in ``begin`` (the selection plan depends on engine scores, so the
  architecture cannot buffer ahead), and the selected b-row batch is
  re-gathered on host and re-uploaded every step;
* ``fused`` — ``presample_fused``: candidate plans are pure cursor math,
  so the ``DataPlane`` pre-gathers + uploads B-row pools depth-ahead on
  worker threads (the finalize protocol); the pool is scored where it
  lands, only the (B,) score vector comes down, and the b winners are
  gathered ON DEVICE.

The workload models the regime the fused data path exists for: candidate
gathers carry a seeded bimodal latency — a ``spike_p`` chance of a stall
sized to the pool (``spike_ms_per_row``·B: a remote-corpus fetch of B
rows / page-cache miss storm), else ~instant. Identical schedule for
both modes (keyed on the gathered ids; the candidate plans are
identical). Stalls SLEEP with the GIL released — the
``benchmarks/data_plane.py`` methodology — so the comparison measures
pipelining, not single-core CPU contention. A CONSTANT latency would
not separate the paths (both hide one gather behind the async in-flight
update); what the host path structurally cannot do is absorb a spike
TALLER than one update, which the fused plane's depth-3 pool buffer
soaks up and refills during quiet gathers. A ``spike_p=0`` control at
ratio 3 records the compute-bound interpret-mode floor, where the two
paths tie to noise on this 1-core CPU.

Each (mode, ratio, b) run also snapshots the transfer counters — the
byte-level side of the claim: the fused train path re-uploads only the
(b,) index + weight vectors (``engine.h2d_bytes``) instead of the full
b-row batch (``loop.h2d_bytes``), and the plans stay bitwise identical
(signature streams asserted equal per config).

Stats are interquartile means over per-step wall-clock (callback to
callback, first 5 steps dropped to shed compile) — regenerate only on an
idle machine. Artifact: benchmarks/artifacts/BENCH_fused.json.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import emit, iqm, save_json


class _SpikySource:
    """A source whose gathers carry seeded bimodal latency (sleep, GIL
    released) — the remote-read disturbance both modes see identically,
    since their candidate plans (and so gathered ids) are identical."""

    def __init__(self, inner, spike_p: float, spike_ms: float):
        self.inner = inner
        self.spike_p, self.spike_ms = float(spike_p), float(spike_ms)
        self.n = inner.n
        self.host_id, self.n_hosts = inner.host_id, inner.n_hosts

    def global_indices(self, state, size):
        return self.inner.global_indices(state, size)

    def local_indices(self, state, size):
        return self.inner.local_indices(state, size)

    def gather(self, indices, epoch=0):
        if self.spike_p:
            rng = np.random.default_rng(np.random.SeedSequence(
                [int(np.asarray(indices)[0]), int(epoch), 777]))
            if rng.uniform() < self.spike_p:
                time.sleep(self.spike_ms / 1e3)
        return self.inner.gather(indices, epoch=epoch)

    def batch(self, state, size):
        batch = self.gather(self.local_indices(state, size),
                            epoch=state.epoch)
        return batch, state.advance(size, self.n)


def _run_mode(mode: str, ratio: int, b: int, steps: int, spike_p: float,
              spike_ms: float, obs_dir: str, seq_len=16):
    from repro import obs
    from repro.api import Experiment
    from repro.api.hooks import Hook
    from repro.configs import get_config
    from repro.configs.base import (DataConfig, ISConfig, ObsConfig,
                                    OptimConfig, RunConfig, SamplerConfig,
                                    ShapeConfig)
    from repro.data.pipeline import SyntheticLM

    run = RunConfig(
        model=get_config("lm-tiny"),
        shape=ShapeConfig("bench", seq_len=seq_len, global_batch=b,
                          kind="train"),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        # tau_th ~1 keeps the IS branch hot so every step pays the full
        # B-row pool assembly + scoring + race-WOR selection
        imp=ISConfig(enabled=True, presample_ratio=ratio, tau_th=1.0001,
                     presample_impl=mode),
        sampler=SamplerConfig(scheme="presample",
                              host_score=(mode == "host")),
        data=DataConfig(prefetch_depth=3, device_put=True),
        obs=ObsConfig(enabled=True, dir=obs_dir),
        remat=False)
    src = _SpikySource(SyntheticLM(run.model.vocab_size, seq_len,
                                   n_examples=1 << 14, seed=3, host_id=0,
                                   n_hosts=1), spike_p, spike_ms)

    class _Rec(Hook):
        def __init__(self):
            self.sigs = []

        def on_step_start(self, loop, step, batch, meta):
            self.sigs.append(meta.signature())

    rec, stamps = _Rec(), []
    exp = Experiment(run, source=src)
    obs.reset()                      # isolate this run's counters
    exp.fit(hooks=[rec], callback=lambda i, m: stamps.append(
        time.perf_counter()), steps=steps)
    snap = obs.snapshot()
    dts = np.diff(np.asarray(stamps))[5:]
    return {"mode": mode, "ratio": ratio, "b": b, "steps": steps,
            "spike_p": spike_p, "spike_ms": spike_ms,
            "ms_per_step": iqm(dts) * 1e3,
            "ms_per_step_p50": float(np.median(dts) * 1e3),
            "ms_per_step_mean": float(np.mean(dts) * 1e3),
            # the transfer ledger, per step: pool H2D (worker or engine),
            # train-path H2D (full batch vs index+weights), score D2H
            "pool_h2d_B": (snap.get("plane.device_put_bytes", 0)
                           + snap.get("engine.h2d_bytes", 0)) / steps,
            "trainpath_h2d_B": (snap.get("loop.h2d_bytes", 0) / steps
                                if mode == "host"
                                else snap.get("engine.h2d_bytes", 0) / steps),
            "score_d2h_B": snap.get("sampler.d2h_bytes", 0) / steps,
            "device_put_skipped": snap.get("plane.device_put_skipped", 0),
            "plan_sigs": rec.sigs}


def bench_fused_presample(ratios=(2, 3, 5), bs=(256, 1024), steps=18,
                          spike_p=0.45, spike_ms_per_row=0.85):
    """host_score vs fused presample sweep → BENCH_fused.json."""
    from repro import obs
    from repro.configs.base import ObsConfig

    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        for b in bs:
            for ratio in ratios:
                spike_ms = spike_ms_per_row * ratio * b
                host = _run_mode("host", ratio, b, steps, spike_p,
                                 spike_ms, tmp)
                fused = _run_mode("fused", ratio, b, steps, spike_p,
                                  spike_ms, tmp)
                assert host.pop("plan_sigs") == fused.pop("plan_sigs"), (
                    f"ratio{ratio}.b{b}: fused plans diverged from host")
                out[f"ratio{ratio}.b{b}.host"] = host
                out[f"ratio{ratio}.b{b}.fused"] = fused
                speed = host["ms_per_step"] / fused["ms_per_step"]
                shrink = (host["trainpath_h2d_B"]
                          / max(fused["trainpath_h2d_B"], 1.0))
                emit(f"fused.ratio{ratio}.b{b}.host.ms_per_step",
                     round(host["ms_per_step"], 2))
                emit(f"fused.ratio{ratio}.b{b}.fused.ms_per_step",
                     round(fused["ms_per_step"], 2),
                     f"host/fused={speed:.3f} "
                     f"trainpath_h2d_shrink={shrink:.1f}x "
                     f"plans_identical=True")
        # spike_p=0 control at ratio 3: the compute-bound floor
        # (interpret kernels on CPU — no latency to absorb, the paths
        # tie to noise on one core)
        for b in bs:
            host = _run_mode("host", 3, b, steps, 0.0, 0.0, tmp)
            fused = _run_mode("fused", 3, b, steps, 0.0, 0.0, tmp)
            assert host.pop("plan_sigs") == fused.pop("plan_sigs")
            out[f"control_quiet.b{b}.host"] = host
            out[f"control_quiet.b{b}.fused"] = fused
            emit(f"fused.control_quiet.b{b}.ms_per_step", None,
                 f"host={host['ms_per_step']:.1f} "
                 f"fused={fused['ms_per_step']:.1f}")
    obs.configure(ObsConfig())       # leave the process registry as found
    save_json("BENCH_fused", out)
    return out

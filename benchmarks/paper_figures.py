"""Paper-validation benchmarks — one per figure/table of
Katharopoulos & Fleuret (ICML 2018).

The paper's experiments are single-output classification (CIFAR / MIT67 /
permuted-MNIST-as-sequence). We reproduce that setting exactly with
``SyntheticCLS`` (loss on the final position only, heterogeneous per-sample
difficulty) on CPU-scale models; fig5 uses the reduced xLSTM (the paper's
LSTM analog). Wall-clock budgets are replaced by the paper's own cost model
(forward = 1, backward = 2 ⇒ IS step with B=3b costs 2× a uniform step) —
this container's CPU timing is not TPU wall-clock.

fig1  variance reduction vs uniform        (paper Fig. 1)
fig2  score ↔ true-gradient-norm fidelity  (paper Fig. 2; SSE loss≫ub)
fig3  convergence at equal cost            (paper Fig. 3)
fig4  fine-tuning                          (paper Fig. 4)
fig5  recurrent sequence classification    (paper Fig. 5)
fig7  pre-sample size B ablation           (paper Fig. 7)
tau   τ-gate switch-on behaviour           (Algorithm 1)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_model, emit, save_json
from repro.configs.base import ISConfig, OptimConfig, RunConfig, ShapeConfig
from repro.core import importance as imp
from repro.core.variance import correlation_sse, grad_distance_reduction
from repro.data.pipeline import PipelineState, SyntheticCLS
from repro.models.lm import LM
from repro.api import Experiment as Trainer

SEQ = 16
VOCAB = 128


def _make(method, *, d=48, layers=2, b=16, ratio=3, tau_th=1.3, lr=2e-3,
          seed=0, data_seed=5, model_cfg=None):
    cfg = model_cfg or bench_model(d=d, layers=layers, vocab=VOCAB)
    shape = ShapeConfig("bench", seq_len=SEQ, global_batch=b, kind="train")
    icfg = ISConfig(enabled=method != "uniform", presample_ratio=ratio,
                    tau_th=tau_th,
                    score_by="loss" if method == "loss" else "upper-bound")
    run = RunConfig(model=cfg, shape=shape,
                    optim=OptimConfig(name="adamw", lr=lr, weight_decay=0.0),
                    imp=icfg, remat=False, seed=seed)
    src = SyntheticCLS(VOCAB, SEQ, seed=data_seed, host_id=0, n_hosts=1)
    tr = Trainer(run, source=src, gate="never" if method == "uniform" else None)
    return cfg, tr


def _test_error(lm, params, src, n=256):
    batch, _ = src.batch(PipelineState(epoch=987), n)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    logits, _ = jax.jit(lm.logits)(params, batch)
    pred = np.asarray(jnp.argmax(logits[:, -1], -1))
    return float(np.mean(pred != np.asarray(batch["labels"][:, -1])))


def _trained_cls(steps=250, seed=0):
    cfg, tr = _make("uniform", seed=seed)
    state, _ = tr.fit(steps=steps)
    return cfg, LM(cfg), state["params"], tr.source


def fig1_variance_reduction():
    """Paper Fig. 1: ‖Ḡ_B − weighted Ḡ_b‖ per scheme / uniform."""
    cfg, lm, params, src = _trained_cls()
    batch, _ = src.batch(PipelineState(epoch=7), 96)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    out = grad_distance_reduction(lm, params, batch, b=24,
                                  key=jax.random.PRNGKey(0), n_rounds=10)
    save_json("fig1_variance_reduction", out)
    for k in ("uniform", "loss", "upper-bound", "gradient-norm"):
        emit(f"fig1.grad_distance_ratio.{k.replace('-', '_')}", None,
             f"{out[k]:.3f}")
    ok = out["upper-bound"] < 1.0 and \
        out["upper-bound"] <= out["loss"] + 0.05
    emit("fig1.claim.upper_bound_reduces_variance", None, f"pass={ok}")
    return out


def fig2_correlation():
    """Paper Fig. 2: Ĝ ≈ the oracle gradient norm; loss is much looser.
    (Paper: SSE 0.017 loss vs 0.002 upper-bound — an ~8× gap.)"""
    cfg, lm, params, src = _trained_cls()
    batch, _ = src.batch(PipelineState(epoch=3), 128)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    sse, dists = correlation_sse(lm, params, batch)
    corr_ub = float(np.corrcoef(np.asarray(dists["upper-bound"]),
                                np.asarray(dists["gradient-norm"]))[0, 1])
    corr_loss = float(np.corrcoef(np.asarray(dists["loss"]),
                                  np.asarray(dists["gradient-norm"]))[0, 1])
    out = {"sse": sse, "corr_upper_bound": corr_ub, "corr_loss": corr_loss,
           "sse_ratio_loss_over_ub": sse["loss"] / max(sse["upper-bound"], 1e-12)}
    save_json("fig2_correlation", out)
    emit("fig2.sse.loss", None, f"{sse['loss']:.5f}")
    emit("fig2.sse.upper_bound", None, f"{sse['upper-bound']:.5f}")
    emit("fig2.sse.ratio_loss_over_ub", None,
         f"{out['sse_ratio_loss_over_ub']:.2f}")
    emit("fig2.corr.upper_bound", None, f"{corr_ub:.4f}")
    emit("fig2.corr.loss", None, f"{corr_loss:.4f}")
    emit("fig2.claim.upper_bound_tighter_than_loss", None,
         f"pass={sse['upper-bound'] < sse['loss'] and corr_ub > corr_loss}")
    return out


def _run_budgeted(method, steps, **kw):
    cfg, tr = _make(method, **kw)
    state, hist = tr.fit(steps=steps)
    lm = LM(cfg)
    te = _test_error(lm, state["params"], tr.source)
    return hist, te


def fig3_convergence(steps=150):
    """Paper Fig. 3: equal cost budget; cost model fwd=1/bwd=2 ⇒ uniform
    gets 2× the steps of an IS method with B=3b. Also reports the
    equal-STEPS comparison, which isolates the variance-reduction effect
    from the scoring overhead."""
    out = {}
    for method, n in (("uniform", 2 * steps), ("uniform-equal-steps", steps),
                      ("loss", steps), ("upper-bound", steps)):
        tls, tes = [], []
        for seed in range(3):
            hist, te = _run_budgeted(
                "uniform" if method.startswith("uniform") else method,
                n, seed=seed)
            tls.append(np.mean([h["loss"] for h in hist[-10:]]))
            tes.append(te)
        out[method] = {"train_loss": float(np.mean(tls)),
                       "test_error": float(np.mean(tes)), "steps": n}
        emit(f"fig3.convergence.{method.replace('-', '_')}", None,
             f"train={out[method]['train_loss']:.4f};"
             f"test_err={out[method]['test_error']:.3f};steps={n}")
    # the paper's headline metric is TEST error at an equalised budget
    ok_test = out["upper-bound"]["test_error"] <= out["uniform"]["test_error"]
    ok_steps = out["upper-bound"]["train_loss"] \
        <= out["uniform-equal-steps"]["train_loss"] * 1.05
    emit("fig3.claim.upper_bound_beats_uniform_test_error_equal_cost",
         None, f"pass={ok_test}")
    emit("fig3.claim.upper_bound_beats_uniform_equal_steps",
         None, f"pass={ok_steps}")
    save_json("fig3_convergence", out)
    return out


def fig4_finetune(steps=80):
    """Paper Fig. 4: fine-tune a pretrained model on a shifted task — most
    samples are handled early, IS focuses on the rest."""
    cfg, lm, params, _ = _trained_cls(steps=250, seed=1)
    out = {}
    for method in ("uniform", "upper-bound"):
        n = 2 * steps if method == "uniform" else steps
        cfg2, tr = _make(method, data_seed=11, tau_th=1.1, lr=1e-3,
                         model_cfg=cfg)
        state, pstate = tr.init_state()
        state["params"] = params          # warm start
        state["opt"] = tr.opt.init(params)
        hist = []
        for i in range(n):
            batch, pstate = tr.source.batch(pstate, tr.B)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, m = tr.step_fn(state, batch)
            hist.append({k: float(v) for k, v in m.items()
                         if getattr(v, "ndim", 0) == 0})
        te = _test_error(lm, state["params"], tr.source)
        out[method] = {
            "train_loss": float(np.mean([h["loss"] for h in hist[-10:]])),
            "test_error": te,
            "is_frac": float(np.mean([h.get("is_active", 0) for h in hist]))}
        emit(f"fig4.finetune.{method.replace('-', '_')}", None,
             f"train={out[method]['train_loss']:.4f};"
             f"test_err={te:.3f};is_frac={out[method]['is_frac']:.2f}")
    ok = out["upper-bound"]["test_error"] <= out["uniform"]["test_error"] + 0.03
    emit("fig4.claim.is_effective_for_finetuning", None, f"pass={ok}")
    save_json("fig4_finetune", out)
    return out


def fig5_sequence(steps=100):
    """Paper Fig. 5: recurrent sequence classification (xLSTM reduced —
    the framework's LSTM-family arch) with IS."""
    from repro.configs import get_config
    from repro.configs.base import reduced
    xcfg = dataclasses.replace(reduced(get_config("xlstm-350m"), repeats=1),
                               vocab_size=VOCAB, dtype="float32")
    out = {}
    for method, n in (("uniform", 2 * steps), ("loss", steps),
                      ("upper-bound", steps)):
        # paper §4.4 sets a conservative tau_th (1.8): IS starts only when
        # variance reduction is substantial; it also reports loss-sampling
        # HURTING the RNN — we check the same ordering
        hist, te = _run_budgeted(method, n, model_cfg=xcfg, b=8, lr=2e-3,
                                 tau_th=1.8, seed=3)
        out[method] = {
            "train_loss": float(np.mean([h["loss"] for h in hist[-10:]])),
            "test_error": te}
        emit(f"fig5.sequence.{method.replace('-', '_')}", None,
             f"train={out[method]['train_loss']:.4f};test_err={te:.3f}")
    emit("fig5.claim.upper_bound_no_worse_than_loss_on_recurrent", None,
         f"pass={out['upper-bound']['train_loss'] <= out['loss']['train_loss'] * 1.1}")
    save_json("fig5_sequence", out)
    return out


def fig7_ablation_B(steps=100):
    """Paper Fig. 7: larger B ⇒ more variance-reduction headroom."""
    out = {}
    for ratio in (2, 3, 6):
        hist, te = _run_budgeted("upper-bound", steps, ratio=ratio, tau_th=1.2)
        out[f"B={ratio}b"] = {
            "train_loss": float(np.mean([h["loss"] for h in hist[-10:]])),
            "test_error": te}
        emit(f"fig7.ablation.B_ratio_{ratio}", None,
             f"train={out[f'B={ratio}b']['train_loss']:.4f}")
    save_json("fig7_ablation_B", out)
    return out


def tau_gate_behaviour(steps=150):
    """Algorithm 1's τ gate: uniform early, IS on once τ_ema > τ_th."""
    cfg, tr = _make("upper-bound", tau_th=1.5)
    state, hist = tr.fit(steps=steps)
    taus = [h["tau"] for h in hist]
    acts = [h["is_active"] for h in hist]
    first_on = next((i for i, a in enumerate(acts) if a > 0), None)
    out = {"first_is_step": first_on, "tau_start": taus[0],
           "tau_end": taus[-1], "is_frac": float(np.mean(acts))}
    save_json("tau_gate", out)
    emit("tau.gate.first_is_step", None, str(first_on))
    emit("tau.gate.is_frac", None, f"{out['is_frac']:.2f}")
    emit("tau.gate.tau_final", None, f"{taus[-1]:.2f}")
    emit("tau.claim.gate_delays_then_activates", None,
         f"pass={first_on is not None and first_on > 0}")
    return out

"""Dry-run profiler: per-op cost breakdown (trip-count-multiplied) for one
(arch × shape × mesh × variant) cell. The §Perf loop's 'profile'.

    PYTHONPATH=src python -m benchmarks.profile_cell gemma3-12b train_4k \
        pod is_fused
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import sys
from collections import defaultdict

import jax


def profile(arch, shape, mesh_kind="pod", variant="is_fused", topn=25):
    from repro.launch.dryrun import build_cell, mesh_ctx
    from repro.launch import hlo_cost as hc

    mesh, fn, args, meta, _score = build_cell(arch, shape, mesh_kind, variant)
    with mesh_ctx(mesh):
        compiled = fn.lower(*args).compile()
    text = compiled.as_text()
    comps, entry = hc.parse_hlo(text)

    shape_of = {c: {op["name"]: op["result"] for op in ops}
                for c, ops in comps.items()}

    # per-op accumulation with trip multipliers
    rows_bytes = defaultdict(float)
    rows_flops = defaultdict(float)
    rows_coll = defaultdict(float)

    def operand_bytes(cn, t, individually=False):
        out = []
        for m in hc._NAME_RE.finditer(t):
            shp = shape_of.get(cn, {}).get(m.group(1))
            if shp:
                out.append(hc._shape_elems_bytes(shp)[1])
        return out if individually else sum(out)

    def walk(cn, mult):
        for op in comps.get(cn, ()):
            o = op["op"]
            if o == "while":
                import re
                mcond = re.search(r"condition=%?([\w.\-]+)", op["attrs"])
                mbody = re.search(r"body=%?([\w.\-]+)", op["attrs"])
                trips = hc._trip_count(comps.get(mcond.group(1), ()))
                walk(mbody.group(1), mult * trips)
                continue
            if o in ("call", "conditional"):
                for c in op["called"]:
                    walk(c, mult)
                continue
            if o == "fusion":
                for c in op["called"]:
                    walk(c, mult)
            key = op["attrs"].split("op_name=\"")
            tag = key[1].split("\"")[0][-80:] if len(key) > 1 else op["name"]
            if o in ("dot", "convolution"):
                first = hc._NAME_RE.search(op["operands"])
                lhs = shape_of.get(cn, {}).get(first.group(1)) if first else None
                rows_flops[f"{o}:{tag}"] += mult * hc._dot_flops(
                    op["result"], lhs, op["attrs"])
            base = o.split("-start")[0]
            if base in hc.COLLECTIVES and not o.endswith("-done"):
                rows_coll[f"{base}:{tag}"] += mult * hc._shape_elems_bytes(
                    op["result"])[1]
            b = hc._bytes_for_op(
                op, lambda t, individually=False: operand_bytes(cn, t, individually),
                lambda t: hc._shape_elems_bytes(t)[1])
            if b:
                rows_bytes[f"{o}:{tag}"] += mult * b

    walk(entry, 1)

    def top(d, n=topn):
        return sorted(d.items(), key=lambda kv: -kv[1])[:n]

    print(f"=== {arch} {shape} {mesh_kind} {variant} ===")
    print("-- top bytes (GB, trip-multiplied, per chip) --")
    for k, v in top(rows_bytes):
        print(f"{v / 1e9:10.2f}  {k}")
    print("-- top flops (GF) --")
    for k, v in top(rows_flops, 12):
        print(f"{v / 1e9:10.1f}  {k}")
    print("-- top collectives (GB) --")
    for k, v in top(rows_coll, 15):
        print(f"{v / 1e9:10.3f}  {k}")


if __name__ == "__main__":
    profile(*sys.argv[1:])

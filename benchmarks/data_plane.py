"""Data-plane pipelining benchmark (the PR-4 tentpole's perf trajectory).

End-to-end training step wall-clock with the paper's presample scheme
(plans of B = ratio·b candidate rows), comparing:

* ``singleslot`` — depth-1 plane: the old ``Prefetcher`` shape (at most
  one batch buffered ahead; one slow gather stalls the very next step);
* ``depthN``     — the pipelined ``DataPlane`` (depth 3 here): the
  credit-bounded buffer refills during quiet gathers and absorbs
  latency SPIKES instead of surfacing them as step stalls.

The workload is a memmapped corpus whose gathers carry a seeded,
deterministic bimodal latency (``spike_p`` chance of a ``spike_ms``
stall, else ~instant — identical schedule for both configs since the
plans are identical). That is the regime the depth exists for: remote
corpus reads, page-cache misses, preprocessing stragglers. With
near-constant assembly latency a single slot already hides everything
and extra depth is pure queue overhead — set ``spike_p=0`` to see that
regime. Stalls sleep (GIL released), so the comparison measures
pipelining, not CPU contention; the device-put stage is likewise off for
both configs (it exists for accelerator H2D, on CPU it only adds
dispatch contention).

Stats are interquartile means over per-step wall-clock (callback to
callback, first 5 steps dropped to shed compile) — regenerate only on an
idle machine. Artifact: benchmarks/artifacts/BENCH_pipeline.json.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, iqm, save_json


class _SpikySource:
    """Wraps a source with seeded bimodal per-gather latency (the spike
    schedule keys on the gathered ids, so every pipeline config sees the
    identical disturbance)."""

    def __init__(self, inner, spike_p: float, spike_ms: float):
        self.inner = inner
        self.spike_p, self.spike_ms = float(spike_p), float(spike_ms)
        self.n = inner.n
        self.host_id, self.n_hosts = inner.host_id, inner.n_hosts

    def global_indices(self, state, size):
        return self.inner.global_indices(state, size)

    def local_indices(self, state, size):
        return self.inner.local_indices(state, size)

    def gather(self, indices, epoch=0):
        if self.spike_p:
            rng = np.random.default_rng(np.random.SeedSequence(
                [int(np.asarray(indices)[0]), int(epoch), 1234]))
            if rng.uniform() < self.spike_p:
                time.sleep(self.spike_ms / 1e3)
        return self.inner.gather(indices, epoch=epoch)

    def batch(self, state, size):
        batch = self.gather(self.local_indices(state, size),
                            epoch=state.epoch)
        return batch, state.advance(size, self.n)


def _corpus(tmp: Path, tokens=1 << 18, vocab=256) -> Path:
    path = tmp / "bench_corpus.npy"
    rng = np.random.default_rng(0)
    np.save(path, rng.integers(0, vocab, size=tokens).astype(np.int32))
    return path


def _run_mode(depth: int, ratio: int, steps: int, corpus: Path,
              spike_p: float, spike_ms: float):
    from repro.api import Experiment
    from repro.configs import get_config
    from repro.configs.base import (DataConfig, ISConfig, OptimConfig,
                                    RunConfig, SamplerConfig, ShapeConfig)
    from repro.data.pipeline import MemmapLM

    cfg = get_config("lm-tiny")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("bench", seq_len=64, global_batch=16, kind="train"),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        # tau_th ~1 keeps the IS branch hot so every step pays the full
        # B-row assembly + on-device scoring
        imp=ISConfig(enabled=True, presample_ratio=ratio, tau_th=1.0001),
        sampler=SamplerConfig(scheme="presample"),
        data=DataConfig(prefetch_depth=depth, device_put=False),
        remat=False)
    src = _SpikySource(MemmapLM(corpus, seq_len=64, seed=3, host_id=0,
                                n_hosts=1), spike_p, spike_ms)
    tr = Experiment(run, source=src)
    stamps, losses = [], []

    def cb(i, m):
        stamps.append(time.perf_counter())
        losses.append(m["loss"])

    tr.fit(steps=steps, callback=cb)
    dts = np.diff(np.asarray(stamps))[5:]
    return {"depth": depth, "ratio": ratio, "steps": steps,
            "spike_p": spike_p, "spike_ms": spike_ms,
            "ms_per_step": iqm(dts) * 1e3,
            "ms_per_step_p50": float(np.median(dts) * 1e3),
            "final_loss": float(np.mean(losses[-5:]))}


def bench_data_plane(ratios=(2, 3, 5), steps=60, depth=3, spike_p=0.45,
                     spike_ms=130.0):
    """Single-slot prefetch vs depth-N DataPlane → BENCH_pipeline.json."""
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        corpus = _corpus(Path(tmp))
        for ratio in ratios:
            single = _run_mode(1, ratio, steps, corpus, spike_p, spike_ms)
            deep = _run_mode(depth, ratio, steps, corpus, spike_p, spike_ms)
            out[f"ratio{ratio}.singleslot"] = single
            out[f"ratio{ratio}.depth{depth}"] = deep
            emit(f"pipeline.ratio{ratio}.singleslot.ms_per_step",
                 round(single["ms_per_step"], 2),
                 f"final_loss={single['final_loss']:.4f}")
            emit(f"pipeline.ratio{ratio}.depth{depth}.ms_per_step",
                 round(deep["ms_per_step"], 2),
                 f"final_loss={deep['final_loss']:.4f}")
            emit(f"pipeline.ratio{ratio}.depth_speedup", None,
                 f"singleslot/depth{depth}="
                 f"{single['ms_per_step'] / deep['ms_per_step']:.3f}")
    save_json("BENCH_pipeline", out)
    return out

"""Scoring-cost microbenchmarks (paper §3.2-3.3: the score must be ~free
relative to the forward pass).

Times the three scoring implementations per call (CPU numbers — relative
cost is what matters here; the TPU story is in §Roofline/§Perf via the
dry-run bytes) and the forward pass itself for scale.

``bench_scoring_overlap`` is the tentpole tracker: end-to-end step
wall-clock of the decoupled scoring engine, synchronous vs overlapped
(score batch k+1 behind update k) vs the serial on-device Algorithm 1, at
``presample_ratio`` ∈ {2, 3, 5} → ``BENCH_scoring.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_model, emit, iqm, save_json, timeit
from repro.models.lm import LM, token_stats_chunked, token_stats_fused, token_stats_naive


def scoring_overhead():
    rng = np.random.RandomState(0)
    T, V = 512, 8192
    z = jnp.asarray(rng.randn(T, V).astype(np.float32))
    y = jnp.asarray(rng.randint(0, V, (T,)))

    fns = {
        "naive": jax.jit(token_stats_naive),
        "chunked": jax.jit(token_stats_chunked),
        "fused": jax.jit(token_stats_fused),
    }
    out = {}
    for name, fn in fns.items():
        us = timeit(fn, z, y, iters=10)
        out[name] = us
        emit(f"score.{name}.us_per_call", round(us, 1), f"T={T},V={V}")

    # pallas kernel (interpret mode on CPU — correctness/time shape only)
    from repro.kernels.ce_score.ops import ce_score
    us = timeit(lambda: ce_score(z, y), iters=2, warmup=1)
    emit("score.pallas_interpret.us_per_call", round(us, 1),
         "interpret-mode; TPU timing n/a in container")

    # scoring vs model forward (the paper's "single forward pass" claim):
    cfg = bench_model(d=128, layers=4, vocab=V)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
             "labels": jnp.zeros((8, 64), jnp.int32)}
    fwd = jax.jit(lambda p, b: lm.logits(p, b)[0])
    us_fwd = timeit(fwd, params, batch, iters=10)
    stats = jax.jit(lambda p, b: lm.sample_stats(p, b))
    us_stats = timeit(stats, params, batch, iters=10)
    emit("score.forward_only.us_per_call", round(us_fwd, 1), "logits only")
    emit("score.forward_plus_score.us_per_call", round(us_stats, 1),
         f"overhead={(us_stats / us_fwd - 1) * 100:.1f}%")
    return out


# ---------------------------------------------------------------------------
# sync vs overlapped engine scoring (the tentpole's perf trajectory)
# ---------------------------------------------------------------------------
def _run_scoring_mode(mode: str, ratio: int, steps: int):
    """One tiny-LM training run; returns mean per-step wall-clock (ms,
    measured callback-to-callback, first 5 steps dropped to shed compile)."""
    from repro.configs import get_config
    from repro.configs.base import (ISConfig, OptimConfig, RunConfig,
                                    SamplerConfig, ShapeConfig)
    from repro.data.pipeline import SyntheticLM
    from repro.api import Experiment as Trainer

    cfg = get_config("lm-tiny")
    host = mode in ("sync", "overlap")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("bench", seq_len=64, global_batch=16, kind="train"),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        # tau_th ~1 keeps the IS branch hot so every step pays scoring
        imp=ISConfig(enabled=True, presample_ratio=ratio, tau_th=1.0001,
                     overlap_scoring=(mode == "overlap")),
        sampler=SamplerConfig(scheme="presample", host_score=host),
        remat=False)
    src = SyntheticLM(cfg.vocab_size, 64, n_examples=2048, seed=3,
                      host_id=0, n_hosts=1)
    tr = Trainer(run, source=src, gate="always" if not host else None)
    stamps, losses = [], []

    def cb(i, m):
        stamps.append(time.perf_counter())
        losses.append(m["loss"])

    tr.fit(steps=steps, callback=cb)
    dts = np.diff(np.asarray(stamps))[5:]
    return {"mode": mode, "ratio": ratio, "steps": steps,
            "ms_per_step": iqm(dts) * 1e3,
            "ms_per_step_p50": float(np.median(dts) * 1e3),
            "final_loss": float(np.mean(losses[-5:]))}


def bench_scoring_overlap(ratios=(2, 3, 5), steps=60):
    """Step wall-clock of the decoupled scoring engine: serial on-device
    Algorithm 1 ("ondevice"), engine scoring on the critical path ("sync"),
    and engine scoring double-buffered behind the update ("overlap").
    Artifact: benchmarks/artifacts/BENCH_scoring.json.
    """
    out = {}
    for ratio in ratios:
        for mode in ("ondevice", "sync", "overlap"):
            r = _run_scoring_mode(mode, ratio, steps)
            out[f"ratio{ratio}.{mode}"] = r
            emit(f"scoring.ratio{ratio}.{mode}.ms_per_step",
                 round(r["ms_per_step"], 2),
                 f"final_loss={r['final_loss']:.4f}")
        sync, ovl = out[f"ratio{ratio}.sync"], out[f"ratio{ratio}.overlap"]
        emit(f"scoring.ratio{ratio}.overlap_speedup", None,
             f"sync/overlap={sync['ms_per_step'] / ovl['ms_per_step']:.3f}")
    save_json("BENCH_scoring", out)
    return out

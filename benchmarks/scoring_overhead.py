"""Scoring-cost microbenchmarks (paper §3.2-3.3: the score must be ~free
relative to the forward pass).

Times the three scoring implementations per call (CPU numbers — relative
cost is what matters here; the TPU story is in §Roofline/§Perf via the
dry-run bytes) and the forward pass itself for scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_model, emit, timeit
from repro.models.lm import LM, token_stats_chunked, token_stats_fused, token_stats_naive


def scoring_overhead():
    rng = np.random.RandomState(0)
    T, V = 512, 8192
    z = jnp.asarray(rng.randn(T, V).astype(np.float32))
    y = jnp.asarray(rng.randint(0, V, (T,)))

    fns = {
        "naive": jax.jit(token_stats_naive),
        "chunked": jax.jit(token_stats_chunked),
        "fused": jax.jit(token_stats_fused),
    }
    out = {}
    for name, fn in fns.items():
        us = timeit(fn, z, y, iters=10)
        out[name] = us
        emit(f"score.{name}.us_per_call", round(us, 1), f"T={T},V={V}")

    # pallas kernel (interpret mode on CPU — correctness/time shape only)
    from repro.kernels.ce_score.ops import ce_score
    us = timeit(lambda: ce_score(z, y), iters=2, warmup=1)
    emit("score.pallas_interpret.us_per_call", round(us, 1),
         "interpret-mode; TPU timing n/a in container")

    # scoring vs model forward (the paper's "single forward pass" claim):
    cfg = bench_model(d=128, layers=4, vocab=V)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
             "labels": jnp.zeros((8, 64), jnp.int32)}
    fwd = jax.jit(lambda p, b: lm.logits(p, b)[0])
    us_fwd = timeit(fwd, params, batch, iters=10)
    stats = jax.jit(lambda p, b: lm.sample_stats(p, b))
    us_stats = timeit(stats, params, batch, iters=10)
    emit("score.forward_only.us_per_call", round(us_fwd, 1), "logits only")
    emit("score.forward_plus_score.us_per_call", round(us_stats, 1),
         f"overhead={(us_stats / us_fwd - 1) * 100:.1f}%")
    return out

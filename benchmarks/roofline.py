"""§Roofline table generator: reads the dry-run artifacts and renders the
per-(arch × shape × mesh) roofline terms, dominant bottleneck, useful-flops
ratio and roofline fraction. Markdown written to
benchmarks/artifacts/roofline.md; CSV rows to stdout via run.py."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"


def load_cells(pattern="*.json", d="dryrun"):
    rows = []
    for f in sorted(glob.glob(str(ART / d / pattern))):
        r = json.loads(Path(f).read_text())
        rows.append(r)
    return rows


def roofline_table(mesh="pod"):
    rows = [r for r in load_cells() if r.get("ok") and r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        f"### Roofline — single-pod (16×16 = 256 chips, v5e)"
        if mesh == "pod" else
        f"### Roofline — multi-pod (2×16×16 = 512 chips)",
        "",
        "| arch | shape | variant | compute (s) | memory (s) | collective (s)"
        " | dominant | useful-FLOPs | roofline frac | peak GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        t = r["terms"]
        uf = r.get("useful_flop_frac")
        rf = r.get("roofline_frac")
        peak = (r.get("memory") or {}).get("peak_bytes") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {uf:.2f} | {rf * 100 if rf else 0:.1f}% | {peak / 1e9:.1f} |")
    return "\n".join(lines)


def skipped_cells():
    """long_500k is skipped for pure full-attention archs (assignment)."""
    from repro.configs import ARCHS, get_config
    from repro.configs.base import applicable_shapes
    out = []
    for arch in ARCHS:
        if arch.startswith("lm-"):
            continue
        cfg = get_config(arch)
        names = {s.name for s in applicable_shapes(cfg)}
        if "long_500k" not in names:
            out.append(arch)
    return out


def render(emit=print):
    md = [roofline_table("pod"), "", roofline_table("multipod"), ""]
    md.append("Skipped cells: `long_500k` for pure full-attention archs "
              "(quadratic attention at 524k): " + ", ".join(skipped_cells()))
    text = "\n".join(md)
    (ART / "roofline.md").write_text(text)
    cells = [r for r in load_cells() if r.get("ok")]
    emit(f"roofline.cells_ok,,{len(cells)}")
    for r in cells:
        t = r["terms"]
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}.{r['variant']},,"
             f"dom={r['dominant'].replace('_s','')};"
             f"frac={(r.get('roofline_frac') or 0) * 100:.1f}%;"
             f"c={t['compute_s']:.2e};m={t['memory_s']:.2e};"
             f"x={t['collective_s']:.2e}")
    return text

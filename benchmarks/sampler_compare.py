"""Scheme comparison: uniform vs presample vs history vs selective.

Trains the same tiny model on SyntheticLM and SyntheticCLS under each
``repro.sampler`` scheme and records loss-vs-wall-clock, so successive PRs
can track whether the cheap persistent-memory schemes (history/selective)
hold their convergence advantage over per-batch presampling. Artifact:
``benchmarks/artifacts/BENCH_sampler.json``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json

SCHEMES = ("uniform", "presample", "history", "selective")


def _run_one(scheme, dataset, steps):
    from repro.configs import get_config
    from repro.configs.base import (ISConfig, OptimConfig, RunConfig,
                                    SamplerConfig, ShapeConfig)
    from repro.data.pipeline import SyntheticCLS, SyntheticLM
    from repro.api import Experiment as Trainer

    cfg = get_config("lm-tiny")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("bench", seq_len=32, global_batch=16, kind="train"),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        imp=ISConfig(enabled=True, presample_ratio=3, tau_th=1.1),
        sampler=SamplerConfig(scheme=scheme, min_coverage=0.25,
                              tau_th=1.005, temperature=0.5),
        remat=False)
    src_cls = {"SyntheticLM": SyntheticLM, "SyntheticCLS": SyntheticCLS}[dataset]
    src = src_cls(cfg.vocab_size, 32, n_examples=1024, seed=13,
                  host_id=0, n_hosts=1)
    tr = Trainer(run, source=src)

    # convergence is judged on a FIXED mixed-difficulty probe set, not the
    # running train loss: SyntheticLM difficulty comes in 1000-id blocks,
    # so the train loss of a sequential scheme swings with batch content
    import jax
    import jax.numpy as jnp
    probe = {k: jnp.asarray(v) for k, v in
             src.gather(np.arange(0, src.n, max(src.n // 64, 1))[:64],
                        epoch=0).items()}
    probe_fn = jax.jit(lambda p: tr.lm.sample_stats(p, probe)[0].mean())

    t0 = time.perf_counter()
    curve = []

    def cb(i, m):
        rec = {"step": i, "t": time.perf_counter() - t0, "loss": m["loss"],
               "active": m.get("sampler_active", m.get("is_active", 0))}
        if i % 5 == 0 or i == steps - 1:
            rec["probe_loss"] = float(probe_fn(tr._last_state["params"]))
        curve.append(rec)

    # keep a handle on the evolving state for the probe
    orig_step = tr.step_fn

    def step_keep(state, *a):
        out = orig_step(state, *a)
        tr._last_state = out[0]
        return out

    tr.step_fn = step_keep
    tr.fit(steps=steps, callback=cb)
    wall = time.perf_counter() - t0
    probes = [c["probe_loss"] for c in curve if "probe_loss" in c]
    return {
        "scheme": scheme, "dataset": dataset, "steps": steps,
        "wall_clock_s": wall,
        # drop compile time from the per-step figure (first step pays the jit)
        "us_per_step": (wall - curve[0]["t"]) / max(steps - 1, 1) * 1e6,
        "final_loss": float(np.mean(probes[-2:])),
        "active_frac": float(np.mean([c["active"] for c in curve])),
        "store_coverage": tr.sampler.store.coverage(),
        "curve": curve,
    }


def sampler_compare(steps=60):
    out = {}
    for dataset in ("SyntheticLM", "SyntheticCLS"):
        for scheme in SCHEMES:
            r = _run_one(scheme, dataset, steps)
            out[f"{dataset}.{scheme}"] = r
            emit(f"sampler.{dataset}.{scheme}", r["us_per_step"],
                 f"final_loss={r['final_loss']:.4f};"
                 f"active={r['active_frac']:.2f};"
                 f"coverage={r['store_coverage']:.2f}")
    # headline: loss reached per second of wall clock, relative to uniform
    for dataset in ("SyntheticLM", "SyntheticCLS"):
        u = out[f"{dataset}.uniform"]
        for scheme in SCHEMES[1:]:
            r = out[f"{dataset}.{scheme}"]
            emit(f"sampler.{dataset}.{scheme}.vs_uniform", None,
                 f"loss_ratio={r['final_loss'] / max(u['final_loss'], 1e-9):.3f};"
                 f"time_ratio={r['wall_clock_s'] / u['wall_clock_s']:.3f}")
    save_json("BENCH_sampler", out)
    return out


if __name__ == "__main__":
    sampler_compare()
    # the tentpole's perf trajectory: sync vs overlapped engine scoring
    from benchmarks.scoring_overhead import bench_scoring_overlap
    bench_scoring_overlap()
